"""Sharding-rule resolution: divisibility fallbacks, FSDP+TP assignment."""
import dataclasses

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (decode_state_pspec, logical_rules,
                                        param_pspec)


class FakeMesh:
    """Duck-typed mesh: .shape dict + .axis_names (pure spec resolution)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_column_parallel():
    spec = param_pspec("layers/attn/wq", (36, 4096, 4096), MESH)
    assert spec[-1] == "model"
    assert spec[-2] == ("pod", "data")


def test_tp_row_parallel():
    spec = param_pspec("layers/attn/wo", (36, 4096, 4096), MESH)
    assert spec[-2] == "model"
    assert spec[-1] == ("pod", "data")


def test_kv_head_fallback():
    # yi-9b: kv_heads*hd = 512 -> 512 % 16 == 0, sharded
    assert param_pspec("layers/attn/wk", (48, 4096, 512), MESH)[-1] == "model"
    # a hypothetical 24-wide kv projection: 24 % 16 != 0 -> no TP, FSDP
    spec = param_pspec("layers/attn/wk", (48, 4096, 24), MESH)
    assert spec[-1] is None
    assert spec[-2] == ("pod", "data")


def test_moe_expert_parallel_vs_tp():
    dbrx = get_config("dbrx-132b")
    spec = param_pspec("layers/ffn/wg", (40, 16, 6144, 10752), MESH, dbrx)
    assert spec[-3] == "model"        # 16 experts % 16 == 0 -> EP
    qwen = get_config("qwen2-moe-a2.7b")
    spec = param_pspec("layers/ffn/wg", (24, 60, 2048, 1408), MESH, qwen)
    assert spec[-3] != "model"        # 60 % 16 != 0 -> falls back to TP
    assert spec[-1] == "model"


def test_norms_not_fsdp():
    assert param_pspec("layers/ln1", (36, 4096), MESH) == P(None, None)


def test_logical_rules_divisibility():
    yi = get_config("yi-9b")
    rules = logical_rules(yi, MESH, global_batch=256)
    assert rules["heads"] == "model"       # 32 % 16
    assert rules["kv_heads"] is None       # 4 % 16 != 0
    assert rules["batch"] == ("pod", "data")
    rules1 = logical_rules(yi, MESH, global_batch=1)
    assert rules1["batch"] is None         # long_500k: batch 1 not divisible


def test_decode_state_specs():
    # stacked key codes (L, B, Hkv, G, g, P): batch->dp, seq/groups->model
    spec = decode_state_pspec("key_codes", (48, 128, 4, 256, 128, 64), MESH,
                              global_batch=128)
    assert spec[1] == ("pod", "data")
    assert "model" in tuple(spec)
    # batch=1: nothing on dp
    spec = decode_state_pspec("key_codes", (48, 1, 4, 4096, 128, 64), MESH,
                              global_batch=1)
    assert spec[1] is None
