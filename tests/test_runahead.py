"""Run-ahead fused decode (DESIGN.md §18).

The load-bearing property mirrors speculative decode's: **bit-identity
by construction**. A run-ahead horizon is one ``lax.scan`` whose body
replays exactly one vanilla decode step — same paged append, same LUT
attention, same sampling op, and the *same RNG split points* (the key
splits once per micro-step in which any slot is live, never after all
finish) — so greedy AND temperature-sampled outputs must equal the H=1
per-token dispatch engine token-for-token. Everything else here guards
the horizon machinery around that: EOS mid-horizon truncation with page
reclamation, cancel racing an in-flight block, quant-group-boundary
commits inside the scan, the event-stream invariants on horizon-shared
timestamps, and the fallback gates that keep spec/QoS/prefix-cache
configurations on the per-token path.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, EngineCore, GenerationConfig, Request,
    StreamingEngine, check_event_stream, stream_latency_stats,
)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _requests(cfg, n=2, seed=5, prompt_len=12, max_new=24):
    """All-arrive-at-once decode-bound workload: with ``n`` <= slots the
    queue drains immediately and the horizon planner engages."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       (prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new, arrival_time=i * 1e-3)
            for i in range(n)]


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens,
                    arrival_time=r.arrival_time) for r in reqs]


def _run(m, params, reqs, *, runahead=0, gen=None, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 128)
    eng = ContinuousBatchingEngine(m, params, runahead=runahead, **kw)
    out = eng.run(_clone(reqs), gen or GenerationConfig())
    return eng, out, {r.rid: list(r.out_tokens) for r in out["requests"]}


# ---------------------------------------------------------------------------
# Bit-identity across horizons: greedy and sampled
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h", [2, 4, 8])
def test_greedy_bit_identical_across_horizons(smoke_model, h):
    cfg, m, params = smoke_model
    reqs = _requests(cfg)
    _, base_out, base = _run(m, params, reqs)
    _, out, toks = _run(m, params, reqs, runahead=h)
    assert toks == base, f"runahead h={h} diverged from per-token decode"
    ra = out["runahead"]
    assert ra["horizons"] > 0, "horizon planner never engaged"
    assert ra["tokens"] > 0
    # every token is emitted exactly once whichever path produced it
    assert out["total_tokens"] == base_out["total_tokens"]


def test_sampled_bit_identical(smoke_model):
    """temperature>0 + top_k: the scan must replay the host loop's RNG
    split points exactly — one split per step in which any slot is live,
    none after all slots finish — or sampled streams diverge."""
    cfg, m, params = smoke_model
    # staggered budgets so slots finish at different micro-steps
    reqs = _requests(cfg, n=2, max_new=17)
    reqs[1].max_new_tokens = 23
    gen = GenerationConfig(temperature=0.8, top_k=8, seed=7)
    _, _, base = _run(m, params, reqs, gen=gen)
    _, out, toks = _run(m, params, reqs, runahead=4, gen=gen)
    assert toks == base, "sampled outputs diverged: RNG split points moved"
    assert out["runahead"]["horizons"] > 0


def test_group_boundary_commit_inside_scan(smoke_model):
    """Lengths crossing a quant-group (page) boundary mid-horizon: the
    scan's paged appends must flush residual groups at the same commit
    points as the per-token loop (paged_append is a pure carry)."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    # appends cross into a fresh group after 3 decode tokens — inside
    # the first H=8 horizon — and again every g tokens after that
    reqs = _requests(cfg, prompt_len=2 * g - 3, max_new=2 * g + 4)
    _, _, base = _run(m, params, reqs)
    _, out, toks = _run(m, params, reqs, runahead=8)
    assert toks == base, "group-boundary commits inside the scan diverged"
    assert out["runahead"]["horizons"] > 0


# ---------------------------------------------------------------------------
# EOS mid-horizon: truncation + page reclamation
# ---------------------------------------------------------------------------


def test_eos_mid_horizon_truncates_and_reclaims(smoke_model):
    cfg, m, params = smoke_model
    reqs = _requests(cfg)
    # pick an eos off the greedy stream so it fires mid-run, mid-horizon
    _, _, base = _run(m, params, reqs)
    eos = base[0][len(base[0]) // 2]
    gen = GenerationConfig(eos_id=int(eos))
    _, base_out, base_toks = _run(m, params, reqs, gen=gen)
    eng, out, toks = _run(m, params, reqs, runahead=8, gen=gen)
    assert toks == base_toks, "EOS truncation diverged from per-token loop"
    assert any(len(t) < r.max_new_tokens
               for t, r in zip(toks.values(), reqs)), \
        "workload never hit EOS — test is vacuous"
    # the horizon ran ahead past EOS on device; the over-run tokens must
    # be dropped at reconcile and the slot's pages reclaimed on drain
    alloc = eng.core.sched.alloc
    assert alloc.free_pages == eng.core.layout.num_pages, \
        "pages leaked after EOS mid-horizon"
    term = check_event_stream(out["events"])
    assert all(k == "finish" for k in term.values())


# ---------------------------------------------------------------------------
# Cancel racing an in-flight horizon
# ---------------------------------------------------------------------------


def test_cancel_while_horizon_in_flight(smoke_model):
    cfg, m, params = smoke_model
    core = EngineCore(m, params, max_slots=2, max_len=128, runahead=4)
    stream = StreamingEngine(core)
    reqs = _requests(cfg, max_new=32)
    for r in reqs:
        stream.submit(r)
    events = []
    for _ in range(200):
        events.extend(stream.step())
        if core._inflight is not None:
            break
    assert core._inflight is not None, "no horizon ever went in flight"
    # cancel must land the in-flight block first: rid 0's horizon tokens
    # surface *before* its cancel event, never after (check_event_stream
    # rejects any post-terminal event)
    assert stream.cancel(reqs[0].rid)
    assert core._inflight is None, "cancel left a horizon in flight"
    while stream.has_work:
        events.extend(stream.step())
    term = check_event_stream(events)
    assert term[reqs[0].rid] == "cancel"
    assert term[reqs[1].rid] == "finish"
    alloc = core.sched.alloc
    assert alloc.free_pages == core.layout.num_pages, \
        "pages leaked after cancel mid-horizon"


# ---------------------------------------------------------------------------
# Event-stream semantics of horizon blocks
# ---------------------------------------------------------------------------


def test_horizon_events_share_timestamps(smoke_model):
    """A landed block emits its kept tokens as one span: shared clock
    stamp, (span, span_ix) metadata, dense ordinals — the same shape
    speculative spans use, so the stream checkers apply unchanged."""
    cfg, m, params = smoke_model
    reqs = _requests(cfg)
    _, out, _ = _run(m, params, reqs, runahead=4)
    check_event_stream(out["events"])
    spans = [ev for ev in out["events"]
             if ev.kind in ("first_token", "token") and ev.span > 1]
    assert spans, "no multi-token horizon spans in the stream"
    by_key = {}
    for ev in spans:
        by_key.setdefault((ev.rid, ev.t), []).append(ev)
    multi = [evs for evs in by_key.values() if len(evs) > 1]
    assert multi, "horizon tokens never shared a timestamp"
    for evs in multi:
        assert [e.span_ix for e in evs] == list(range(len(evs)))
        assert len({e.span for e in evs}) == 1
    lat = stream_latency_stats(out["events"], reqs)
    assert lat["itl_s"]["n"] > 0
    assert lat["itl_s"]["p50"] >= 0.0   # intra-span gaps clamp to ~0


# ---------------------------------------------------------------------------
# Fallback gates: incompatible configs stay on the per-token path
# ---------------------------------------------------------------------------


def test_fallback_configs_never_engage(smoke_model):
    cfg, m, params = smoke_model
    reqs = _requests(cfg, n=3, max_new=12)
    _, _, base = _run(m, params, reqs, max_slots=3)

    from repro.serve import QosConfig
    from repro.spec import SpecConfig
    for kw in (dict(spec=SpecConfig(mode="ngram", k=4)),
               dict(qos=QosConfig(ttft_slo=10.0)),
               dict(prefix_cache=True, prefill_chunk=32)):
        _, out, toks = _run(m, params, reqs, runahead=4, max_slots=3, **kw)
        assert out["runahead"]["horizons"] == 0, \
            f"runahead engaged under incompatible config {kw}"
        assert toks == base, f"fallback path diverged under {kw}"


def test_oversubscribed_pool_falls_back(smoke_model):
    """When the pool can't pre-reserve a full horizon the planner falls
    back to H=1 (which can shed/preempt) instead of stalling — outputs
    still match the per-token engine on the same undersized pool."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    reqs = _requests(cfg, n=2, max_new=16)
    pages = 2 * ((12 + 16) // g + 1)   # just enough to finish, no slack
    kw = dict(max_slots=2, max_len=64, num_pages=pages)
    _, _, base = _run(m, params, reqs, **kw)
    _, out, toks = _run(m, params, reqs, runahead=8, **kw)
    assert toks == base


def test_invalid_runahead_rejected(smoke_model):
    _, m, params = smoke_model
    with pytest.raises(ValueError):
        EngineCore(m, params, max_slots=2, max_len=64, runahead=-1)
