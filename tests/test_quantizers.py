"""Quantizer correctness: error bounds, invariants, method comparisons."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import polar
from repro.core.quantizers import (
    QuantConfig, affine_decode, affine_encode, decode_channel_keys,
    decode_polar_keys, decode_token_keys, decode_values, decode_zipcache_keys,
    encode_int_keys, encode_kivi_keys, encode_polar_keys, encode_values,
    encode_zipcache_keys,
)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# Affine quantizer properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.sampled_from(["midrise", "midtread"]),
       st.integers(0, 10_000))
def test_affine_error_bound(bits, mode, seed):
    x = _rand(seed, (4, 37))
    codes, s, z = affine_encode(x, bits, axis=-1, mode=mode)
    xt = affine_decode(codes, s, z, mode)
    err = jnp.abs(x - xt)
    bound = s * 0.5 + 1e-5
    assert bool(jnp.all(err <= bound)), float((err - bound).max())
    assert codes.dtype == jnp.uint8
    assert int(codes.max()) <= (1 << bits) - 1


def test_affine_monotone():
    x = jnp.linspace(-3, 3, 64)[None]
    codes, _, _ = affine_encode(x, 4, axis=-1, mode="midrise")
    c = np.asarray(codes)[0]
    assert (np.diff(c.astype(int)) >= 0).all()


def test_affine_constant_input():
    x = jnp.full((2, 16), 3.14)
    codes, s, z = affine_encode(x, 4, axis=-1, mode="midtread")
    xt = affine_decode(codes, s, z, "midtread")
    np.testing.assert_allclose(np.asarray(xt), 3.14, atol=1e-4)


# ---------------------------------------------------------------------------
# PolarQuant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,t", [(4, 4), (3, 3), (5, 3), (2, 4)])
def test_polar_error_bound(r, t):
    """|k - k~| <= s_rho/2 + (rho + s_rho/2) * s_theta/2 per element."""
    g = 32
    k = _rand(0, (2, 2, 128, 32), 2.0)
    cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=t, group_size=g)
    pk = encode_polar_keys(k, cfg)
    kt = decode_polar_keys(pk)
    rho, _ = polar.to_polar(k)
    rho_g = rho.reshape(2, 2, 4, g, 16)
    bound = (pk.rho_scale * 0.5 + (rho_g + pk.rho_scale * 0.5)
             * pk.theta_scale * 0.5)
    err_x = jnp.abs(k - kt)
    px, py = polar.split_pairs(err_x)
    err_vec = jnp.sqrt(px ** 2 + py ** 2).reshape(2, 2, 4, g, 16)
    assert bool(jnp.all(err_vec <= bound + 1e-4))


def test_polar_code_packing():
    k = _rand(1, (1, 1, 64, 16))
    cfg = QuantConfig(method="polar", rho_bits=5, theta_bits=3, group_size=32)
    pk = encode_polar_keys(k, cfg)
    assert pk.codes.dtype == jnp.uint8
    assert int(pk.rho_codes().max()) <= 31
    assert int(pk.theta_codes().max()) <= 7
    recombined = (pk.rho_codes() << 3) | pk.theta_codes()
    np.testing.assert_array_equal(np.asarray(recombined), np.asarray(pk.codes))


def test_polar_competitive_with_kivi(structured_keys):
    """Paper Table 1: PolarQuant preserves quality comparably to KIVI at
    matched bit width (its *win* is the LUT decode speedup + robustness to
    token-wise collapse, not strictly lower MSE)."""
    k = structured_keys(jax.random.PRNGKey(0), 2, 2, 512, 64)
    cfgp = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=128)
    cfgk = QuantConfig(method="kivi", key_bits=4, group_size=128)
    ep = float(jnp.linalg.norm(k - decode_polar_keys(encode_polar_keys(k, cfgp))))
    ek = float(jnp.linalg.norm(k - decode_channel_keys(encode_kivi_keys(k, cfgk))))
    assert ep < 2.5 * ek, (ep, ek)


def test_polar_beats_token_wise_methods(structured_keys):
    """Table 1's collapse rows: plain token-wise Int-N degrades hard on
    channel-outlier keys; PolarQuant does not. ZipCache's channel-norm
    partially rescues it on this synthetic (real Qwen-style extreme
    outliers are what collapse it in the paper), so the zipcache assertion
    is a bounded-competitive one."""
    k = structured_keys(jax.random.PRNGKey(1), 2, 2, 512, 64)
    cfgp = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=128)
    ep = float(jnp.linalg.norm(k - decode_polar_keys(encode_polar_keys(k, cfgp))))
    cfgi = QuantConfig(method="int", key_bits=4)
    ei = float(jnp.linalg.norm(k - decode_token_keys(encode_int_keys(k, cfgi))))
    cfgz = QuantConfig(method="zipcache", key_bits=4, group_size=128)
    ez = float(jnp.linalg.norm(
        k - decode_zipcache_keys(encode_zipcache_keys(k, cfgz))))
    assert ep < ei, (ep, ei)
    assert ep < 2.0 * ez, (ep, ez)


def test_angle_bits_more_sensitive_than_radius(structured_keys):
    """Paper Table 6 Observation 1: at fixed total bits, spending on the
    angle beats spending on the radius — (r3,t5) < (r4,t4) < (r5,t3) err."""
    k = structured_keys(jax.random.PRNGKey(2), 2, 2, 1024, 64)
    errs = {}
    for r, t in [(5, 3), (4, 4), (3, 5)]:
        cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=t,
                          group_size=128)
        errs[(r, t)] = float(jnp.linalg.norm(
            k - decode_polar_keys(encode_polar_keys(k, cfg))))
    assert errs[(3, 5)] < errs[(4, 4)] < errs[(5, 3)], errs


def test_theta_fixed_grid_variant():
    k = _rand(3, (1, 2, 64, 32))
    cfg = QuantConfig(method="polar", theta_stats="fixed", group_size=32)
    pk = encode_polar_keys(k, cfg)
    kt = decode_polar_keys(pk)
    rel = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
    assert rel < 0.35


# ---------------------------------------------------------------------------
# Baselines + values
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("enc,dec", [
    (encode_kivi_keys, decode_channel_keys),
    (encode_zipcache_keys, decode_zipcache_keys),
])
def test_grouped_baselines_roundtrip(enc, dec):
    k = _rand(4, (2, 2, 128, 32), 3.0)
    cfg = QuantConfig(method="kivi", key_bits=8, group_size=32)
    rel = float(jnp.linalg.norm(k - dec(enc(k, cfg))) / jnp.linalg.norm(k))
    assert rel < 0.01


def test_values_roundtrip():
    v = _rand(5, (2, 2, 64, 32))
    qv = encode_values(v, 8)
    rel = float(jnp.linalg.norm(v - decode_values(qv)) / jnp.linalg.norm(v))
    assert rel < 0.01


def test_bits_accounting():
    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=128)
    assert abs(cfg.key_bits_per_element(128) - 4.25) < 1e-6
    cfg33 = QuantConfig(method="polar", rho_bits=3, theta_bits=3, group_size=128)
    assert abs(cfg33.key_bits_per_element(128) - 3.25) < 1e-6
    kivi = QuantConfig(method="kivi", key_bits=4, group_size=128)
    assert abs(kivi.key_bits_per_element(128) - 4.25) < 1e-6
    kivi32 = QuantConfig(method="kivi", key_bits=4, group_size=32)
    assert abs(kivi32.key_bits_per_element(32) - 5.0) < 1e-6


def test_bits_accounting_uses_actual_head_dim():
    """Int-N per-token stats amortize over the real head_dim, not a
    hardcoded d=128 (the seed bug)."""
    cfg = QuantConfig(method="int", key_bits=4)
    assert abs(cfg.key_bits_per_element(128) - (4 + 32 / 128)) < 1e-6
    assert abs(cfg.key_bits_per_element(64) - (4 + 32 / 64)) < 1e-6
    assert abs(cfg.key_bits_per_element(32) - 5.0) < 1e-6
    # grouped stats don't depend on head_dim
    polar = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                        group_size=128)
    assert polar.key_bits_per_element(32) == polar.key_bits_per_element(128)
    # the fixed theta grid drops the per-group theta stats
    fixed = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                        group_size=128, theta_stats="fixed")
    assert fixed.key_bits_per_element(128) < polar.key_bits_per_element(128)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32, 64]))
def test_polar_roundtrip_hypothesis(seed, g):
    # (4, 4) is the max packed precision (r + t <= 8, one uint8 per pair)
    k = _rand(seed, (1, 1, 2 * g, 8), 4.0)
    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=g)
    kt = decode_polar_keys(encode_polar_keys(k, cfg))
    rel = float(jnp.linalg.norm(k - kt) / (jnp.linalg.norm(k) + 1e-9))
    assert rel < 0.3, rel


def test_overwide_bits_rejected():
    k = _rand(0, (1, 1, 32, 8))
    with pytest.raises(ValueError):
        encode_polar_keys(k, QuantConfig(method="polar", rho_bits=6,
                                         theta_bits=6, group_size=16))
