"""Fused flash-decode path: per-slot lengths, jnp-reference agreement, and
the cfg-driven model dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantConfig, decode_attention, init_cache, prefill
from repro.core import paged_cache as pg
from repro.core.cache_layout import LinearLayout, PagedLayout, PageAllocator
from repro.core.kv_cache import fused_decode_attention
from repro.kernels import ops


def _enc_inputs(seed, b, hkv, qh, d, g, gcount):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    k = jax.random.normal(ks[0], (b, hkv, gcount * g, d))
    q = jax.random.normal(ks[1], (b, hkv * qh, d))
    v = jax.random.normal(ks[2], (b, hkv, gcount * g, d))
    res = jax.random.normal(ks[3], (b, hkv, g, d))
    enc = ops.polar_encode(k, group_size=g, backend="ref")
    return q, enc, res, v


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_per_slot_lengths_match_scalar_calls(backend):
    """Batched (B,) lengths == per-sequence scalar-length calls."""
    b, hkv, qh, d, g, gcount = 3, 2, 4, 32, 16, 4
    q, enc, res, v = _enc_inputs(0, b, hkv, qh, d, g, gcount)
    lengths = jnp.asarray([7, 40, 64], jnp.int32)
    out = ops.polar_decode_attention_full(q, *enc, res, v, None, None,
                                          lengths, backend=backend)
    for i in range(b):
        oi = ops.polar_decode_attention_full(
            q[i : i + 1], *[a[i : i + 1] for a in enc], res[i : i + 1],
            v[i : i + 1], None, None,
            jnp.asarray(int(lengths[i]), jnp.int32), backend=backend)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(oi[0]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("value_bits", [0, 4])
@pytest.mark.parametrize("length", [37, 48, 64])
def test_fused_matches_jnp_decode_attention(value_bits, length):
    """kernel path == pure-jnp decode_attention over the same dense cache."""
    B, H, d, g = 2, 2, 32, 16
    cfg = QuantConfig(method="polar", group_size=g, value_bits=value_bits)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    k = jax.random.normal(k1, (B, H, length, d))
    v = jax.random.normal(k2, (B, H, length, d))
    cache = prefill(init_cache(cfg, B, H, d, 64, layout=LinearLayout(64)),
                    k, v)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, d))
    o_jnp = decode_attention(cache, q)
    for backend in ("ref", "interpret"):
        o_fused = fused_decode_attention(cache, q, backend=backend)
        np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_fused),
                                   atol=2e-5, rtol=1e-4)


def test_fused_vs_jnp_heterogeneous_paged_slots():
    """Gathered paged view with every slot at a different length: the fused
    kernel must agree with the jnp reference slot-by-slot."""
    H, d, g = 2, 32, 16
    lay = PagedLayout(page_size=g, num_pages=24, slots=3, pages_per_slot=6)
    cfg = QuantConfig(method="polar", group_size=g, value_bits=4)
    alloc = PageAllocator(lay)
    cache = pg.init_paged_cache(cfg, lay, H, d)
    for slot, tp in enumerate([9, 38, 64]):
        assert alloc.alloc(slot, lay.pages_for(max(tp, 1)))
        bucket = -(-tp // g) * g
        ks = jax.random.split(jax.random.PRNGKey(slot), 2)
        k = jax.random.normal(ks[0], (1, H, bucket, d))
        v = jax.random.normal(ks[1], (1, H, bucket, d))
        cache = pg.paged_prefill(cache, jnp.asarray(slot),
                                 alloc.table()[slot], k, v,
                                 jnp.asarray(tp))
    q = jax.random.normal(jax.random.PRNGKey(7), (3, H * 2, d))
    o_jnp = pg.paged_decode_attention(cache, q, alloc.table(), backend="jnp")
    for backend in ("ref", "interpret"):
        o_fused = pg.paged_decode_attention(cache, q, alloc.table(),
                                            backend=backend)
        np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_fused),
                                   atol=2e-5, rtol=1e-4)


def test_model_decode_reaches_fused_kernel():
    """cfg.decode_backend routes model decode through
    polar_decode_attention_full; logits must agree with the jnp path."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import get_model

    base = reduce_for_smoke(get_config("tinyllama-1.1b"))
    assert base.quant.method == "polar"
    m = get_model(base)
    params = m.init(jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(
        0, base.vocab_size, (2, 40)).astype(np.int32)
    state0 = m.init_decode_state(2, 128)
    _, state0 = m.prefill(params, {"tokens": jnp.asarray(toks)}, state0)

    logits = {}
    for be in ("jnp", "ref", "interpret"):
        mb = get_model(dataclasses.replace(base, decode_backend=be))
        st = state0
        for i in range(3):
            lg, st = mb.decode(params, st, jnp.asarray(toks[:, i]))
        logits[be] = np.asarray(lg)
    np.testing.assert_allclose(logits["jnp"], logits["ref"],
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(logits["ref"], logits["interpret"],
                               atol=1e-4, rtol=1e-4)


def test_fused_rejects_non_polar():
    cfg = QuantConfig(method="kivi", group_size=16)
    cache = init_cache(cfg, 1, 1, 32, 32, layout=LinearLayout(32))
    with pytest.raises(ValueError):
        fused_decode_attention(cache, jnp.zeros((1, 1, 32)))
